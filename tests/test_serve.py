"""repro.serve: quorum reads, divergence detector, batcher, service, ckpt."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.agg as agg
import repro.exp as exp
from repro.checkpoint import checkpointer as ck
from repro.core.attacks import MODEL_ATTACKS, ByzantineSpec, inject_models
from repro.models.registry import get_bundle
from repro.serve import (DetectorConfig, DivergenceDetector, QuorumService,
                         ReplicaPool, disagreement, quorum_tokens)
from repro.serve.batcher import ContinuousBatcher

R, F = 4, 1


# ---------------------------------------------------------------------------
# read rules
# ---------------------------------------------------------------------------


def test_vote_rule_plurality():
    x = jnp.asarray([[3, 7], [3, 9], [5, 9], [3, 9]], jnp.int32)
    out = agg.get("vote")(x, 1)
    assert out.tolist() == [3, 9]
    # concrete-mask subset semantics
    m = np.asarray([True, False, True, True])
    sub = agg.get("vote")(x, 1, mask=m)
    assert sub.tolist() == agg.get("vote")(x[m], 1).tolist()


@pytest.mark.parametrize("attack", sorted(MODEL_ATTACKS))
@pytest.mark.parametrize("rule", ("median", "vote"))
def test_quorum_reads_survive_every_model_attack(attack, rule):
    key = jax.random.PRNGKey(0)
    honest = jax.random.normal(key, (2, 16))          # [B, V] logits
    stack = jnp.broadcast_to(honest, (R,) + honest.shape) + 0
    spec = ByzantineSpec(server_attack=attack, n_byz_servers=F)
    corrupted = inject_models({"logits": stack}, spec,
                              jax.random.PRNGKey(1))["logits"]
    toks = quorum_tokens(corrupted, F, rule=rule)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(honest, -1)))


def test_disagreement_metric():
    honest = jax.random.normal(jax.random.PRNGKey(2), (3, 8))
    stack = jnp.broadcast_to(honest, (R,) + honest.shape) + 0
    toks = quorum_tokens(stack, F)
    assert disagreement(stack, toks) == 0.0
    flipped = stack.at[-1].set(-stack[-1])
    toks = quorum_tokens(flipped, F)
    assert disagreement(flipped, toks) > 0.0


# ---------------------------------------------------------------------------
# divergence detector
# ---------------------------------------------------------------------------


def test_detector_ejects_attacker_within_patience_reads():
    det = DivergenceDetector(R, F, DetectorConfig(patience=3))
    active = np.ones(R, bool)
    dist = np.array([0.0, 0.0, 0.0, 1.0])
    assert det.observe(dist, active) == []
    assert det.observe(dist, active) == []
    assert det.observe(dist, active) == [3]       # k = patience reads
    assert det.flagged[3] and not det.flagged[:3].any()


def test_detector_never_ejects_honest_on_clean_runs():
    det = DivergenceDetector(R, F)
    rng = np.random.default_rng(0)
    active = np.ones(R, bool)
    for _ in range(50):
        dist = 1.0 + 0.05 * rng.standard_normal(R)  # honest envelope jitter
        assert det.observe(dist, active) == []
    assert not det.flagged.any()


def test_detector_respects_quorum_floor():
    det = DivergenceDetector(3, 1, DetectorConfig(patience=1))
    active = np.ones(3, bool)
    ejected = det.observe(np.array([0.0, 0.0, 5.0]), active)
    assert ejected == []                          # 3 - 1 < 2f+1 = 3
    assert det.flagged[2]                         # still flagged, not ejected


# ---------------------------------------------------------------------------
# replica pool
# ---------------------------------------------------------------------------


def _tiny_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (4, 3)),
            "b": jax.random.normal(k2, (3,))}


def test_replica_pool_constructors_and_validation():
    p = _tiny_params(jax.random.PRNGKey(0))
    pool = ReplicaPool.from_params(p, R, f=F)
    assert pool.n_replicas == R and pool.n_active == R
    assert pool.quorum_floor == 2 * F + 1
    stacked = jax.tree.map(lambda l: jnp.stack([l] * R), p)
    pool2 = ReplicaPool.from_stacked(stacked, f=F)
    assert pool2.n_replicas == R
    for a, b in zip(jax.tree.leaves(pool.single(2)), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="2f"):
        ReplicaPool.from_params(p, 2, f=1)        # n < 2f+1
    with pytest.raises(ValueError, match="active"):
        ReplicaPool(params=stacked, f=F, active=np.ones(R + 1, bool))


def test_consolidated_outvotes_corruption():
    p = _tiny_params(jax.random.PRNGKey(1))
    pool = ReplicaPool.from_params(p, 5, f=2).corrupt(
        ByzantineSpec(server_attack="reversed", n_byz_servers=2),
        jax.random.PRNGKey(2))
    for a, b in zip(jax.tree.leaves(pool.consolidated()),
                    jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="tolerance"):
        ReplicaPool.from_params(p, 5, f=1).corrupt(
            ByzantineSpec(server_attack="random", n_byz_servers=2),
            jax.random.PRNGKey(3))


def test_deactivate_respects_floor():
    p = _tiny_params(jax.random.PRNGKey(4))
    pool = ReplicaPool.from_params(p, R, f=F)
    assert pool.deactivate(3)
    assert pool.n_active == 3
    assert not pool.deactivate(2)                 # would break 2f+1
    assert not pool.deactivate(3)                 # already out


# ---------------------------------------------------------------------------
# batcher (host-side)
# ---------------------------------------------------------------------------


def test_batcher_admission_queue_and_refill():
    b = ContinuousBatcher(n_slots=2, max_queue=2)
    r1, r2 = b.submit([1]), b.submit([2])
    assert [r.rid for r in b.fill()] == [0, 1]
    r3, r4 = b.submit([3]), b.submit([4])
    r5 = b.submit([5])
    assert r5.status == "rejected" and b.rejected == 1
    assert b.fill() == []                         # slots full
    b.finish(r1)
    placed = b.fill()
    assert placed == [r3] and b.refills == 1
    assert b.pending == 1 and not b.idle
    b.finish(r2), b.finish(r3)
    b.fill()
    b.finish(r4)
    assert b.idle


def test_batcher_deadline_expiry():
    b = ContinuousBatcher(n_slots=1)
    req = b.submit([1, 2], deadline_ms=0.0)
    b.fill()
    hit = b.expire()
    assert hit == [req] and req.status == "deadline"
    assert not req.deadline_met and req.latency_s is not None
    assert b.slots[0] is None


# ---------------------------------------------------------------------------
# quorum service (transformer decode path)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bundle():
    return get_bundle("phi4-mini-3.8b", reduced=True)


@pytest.fixture(scope="module")
def tparams(bundle):
    return bundle.init(jax.random.PRNGKey(0))


def _gen(pool, bundle, prompts, max_new, **kw):
    svc = QuorumService(pool, bundle, n_slots=2, max_len=32, **kw)
    return svc.generate(prompts, max_new=max_new), svc


def test_service_token_identity_with_byzantine_replica(bundle, tparams):
    prompts = [[3, 5, 7], [11, 2, 4], [9, 9, 1]]   # 3 requests, 2 slots
    base, _ = _gen(ReplicaPool.from_params(tparams, 1, f=0), bundle,
                   prompts, 5)
    pool = ReplicaPool.from_params(tparams, R, f=F).corrupt(
        ByzantineSpec(server_attack="lie", n_byz_servers=1),
        jax.random.PRNGKey(5))
    outs, svc = _gen(pool, bundle, prompts, 5)
    assert outs == base                           # token-identical
    rep = svc.report()
    assert rep["refills"] >= 1                    # continuous batching kicked in
    assert [i for _, i in rep["ejections"]] == [R - 1]
    assert rep["n_active"] == R - 1
    assert rep["requests"]["done"] == 3


def test_service_clean_run_never_ejects(bundle, tparams):
    outs, svc = _gen(ReplicaPool.from_params(tparams, R, f=F), bundle,
                     [[1, 2, 3]], 4)
    rep = svc.report()
    assert rep["ejections"] == [] and rep["disagreement_rate"] == 0.0
    assert len(outs[0]) == 4


def test_service_deadline_truncates(bundle, tparams):
    pool = ReplicaPool.from_params(tparams, 1, f=0)
    svc = QuorumService(pool, bundle, n_slots=1, max_len=64)
    req = svc.submit([1, 2, 3], max_new=30, deadline_ms=0.0)
    while svc.step():
        pass
    assert req.status == "deadline"
    assert 0 < len(req.out_tokens) < 30
    assert svc.report()["requests"]["deadline"] == 1


def test_service_rejects_vlm_family():
    vlm = get_bundle("qwen2-vl-7b", reduced=True)
    p = _tiny_params(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="token-in"):
        QuorumService(ReplicaPool.from_params(p, 1, f=0), vlm)


# ---------------------------------------------------------------------------
# spec-integrated checkpointing round trip
# ---------------------------------------------------------------------------


def test_ckpt_spec_validation():
    with pytest.raises(ValueError, match="protocol"):
        exp.Experiment(name="x", ckpt_every=5)    # default runner is fused
    with pytest.raises(ValueError, match="ckpt_every"):
        exp.get("serve/ckpt_smoke", ckpt_every=None, ckpt_dir="/tmp/x")
    e = exp.get("serve/ckpt_lie_server")
    assert exp.Experiment.from_dict(e.to_dict()) == e


def test_protocol_ckpt_roundtrip_into_pool(tmp_path):
    d = os.path.join(str(tmp_path), "ck")
    res = exp.run("serve/ckpt_smoke", steps=6, ckpt_every=3, ckpt_dir=d)
    assert ck.latest_step(d) == 6 and sorted(os.listdir(d)) == \
        ["step_00000003", "step_00000006"]
    e = exp.get("serve/ckpt_smoke")
    init_fn, _, _ = e.build_problem()
    pool = ReplicaPool.from_checkpoint(d, init_fn, f=1)
    assert pool.n_replicas == e.n_servers
    for a, b in zip(jax.tree.leaves(pool.params),
                    jax.tree.leaves(res.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # chunked checkpoint emission trains bit-identically to one fused run
    res2 = exp.run("serve/ckpt_smoke", steps=6, ckpt_every=None,
                   ckpt_dir=None)
    for a, b in zip(jax.tree.leaves(res.state.params),
                    jax.tree.leaves(res2.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# elastic re-admission (corrupt -> eject -> heal -> readmit)
# ---------------------------------------------------------------------------


def test_reactivate_heals_from_quorum_median():
    p = _tiny_params(jax.random.PRNGKey(6))
    pool = ReplicaPool.from_params(p, R, f=F).corrupt(
        ByzantineSpec(server_attack="reversed", n_byz_servers=1),
        jax.random.PRNGKey(7))
    assert pool.deactivate(R - 1)
    assert not pool.reactivate(0)          # already active: no-op
    assert pool.reactivate(R - 1)          # healed from the honest median
    assert pool.n_active == R
    for a, b in zip(jax.tree.leaves(pool.single(R - 1)),
                    jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_detector_probation_reejects_on_single_outlier():
    det = DivergenceDetector(R, F, DetectorConfig(patience=3, probation=4))
    active = np.ones(R, bool)
    det.flagged[3] = True
    det.readmit(3)
    assert not det.flagged[3] and det.probation[3] == 4
    dist = np.array([0.0, 0.0, 0.0, 1.0])
    assert det.observe(dist, active) == [3]   # zero patience on probation
    assert det.flagged[3]


def test_detector_probation_expires_back_to_patience():
    det = DivergenceDetector(R, F, DetectorConfig(patience=3, probation=2))
    active = np.ones(R, bool)
    det.readmit(3)
    clean = np.zeros(R)
    det.observe(clean, active)
    det.observe(clean, active)
    assert det.probation[3] == 0              # probation served cleanly
    dist = np.array([0.0, 0.0, 0.0, 1.0])
    assert det.observe(dist, active) == []    # patience rule again
    assert det.observe(dist, active) == []
    assert det.observe(dist, active) == [3]


def test_service_eject_heal_readmit_token_identical(bundle, tparams):
    prompts = [[3, 5, 7], [11, 2, 4]]
    base, _ = _gen(ReplicaPool.from_params(tparams, 1, f=0), bundle,
                   prompts, 5)
    pool = ReplicaPool.from_params(tparams, R, f=F).corrupt(
        ByzantineSpec(server_attack="lie", n_byz_servers=1),
        jax.random.PRNGKey(5))
    svc = QuorumService(pool, bundle, n_slots=2, max_len=32)
    outs = svc.generate(prompts, max_new=5)
    assert outs == base                       # corrupt run stays identical
    rep = svc.report()
    assert rep["n_active"] == R - 1
    assert [i for _, i in rep["ejections"]] == [R - 1]

    assert svc.readmit(R - 1)                 # heal + re-admit
    assert not svc.readmit(R - 1)             # already back: no-op
    assert svc.pool.n_active == R
    assert svc.detector.probation[R - 1] == svc.detector.cfg.probation
    for a, b in zip(jax.tree.leaves(svc.pool.single(R - 1)),
                    jax.tree.leaves(tparams)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    outs2 = svc.generate(prompts, max_new=5)
    assert outs2 == base                      # healed fleet stays identical
    rep2 = svc.report()
    assert rep2["n_active"] == R              # the healed replica stayed in
    assert len(rep2["ejections"]) == 1        # no post-readmit ejections
    assert rep2["replicas"][R - 1]["active"]
    assert not rep2["replicas"][R - 1]["flagged"]
