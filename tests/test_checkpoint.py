"""Checkpointer: roundtrip, atomicity, elastic re-shard, Byzantine-safe
median-of-replicas restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpointer as ck

KEY = jax.random.PRNGKey(0)


def make_state(n_rep=4):
    return {"params": {"w": jax.random.normal(KEY, (n_rep, 6, 4)),
                       "b": jnp.arange(n_rep * 3, dtype=jnp.float32).reshape(n_rep, 3)},
            "step": jnp.asarray(17)}


def test_roundtrip(tmp_path):
    state = make_state()
    d = str(tmp_path / "ckpt")
    ck.save(d, 17, state)
    assert ck.latest_step(d) == 17
    restored, step = ck.restore(d, 17, jax.eval_shape(lambda: state))
    assert step == 17
    np.testing.assert_array_equal(restored["params"]["w"], state["params"]["w"])


def test_multiple_steps_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    s = make_state()
    ck.save(d, 1, s)
    ck.save(d, 5, s)
    ck.save(d, 3, s)
    assert ck.latest_step(d) == 5


def test_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path / "ckpt")
    ck.save(d, 2, make_state())
    assert not any(f.endswith(".tmp") for f in os.listdir(d))


def test_median_restore_outvotes_corruption(tmp_path):
    """A Byzantine-corrupted replica inside the checkpoint is outvoted."""
    state = make_state(n_rep=5)
    state["params"]["w"] = state["params"]["w"].at[4].set(1e9)  # corrupted
    d = str(tmp_path / "ckpt")
    ck.save(d, 1, state)
    collapsed, _ = ck.restore_consolidated(d, 1, jax.eval_shape(lambda: state))
    w = collapsed["params"]["w"]
    assert w.shape == (6, 4)
    assert float(jnp.max(jnp.abs(w))) < 100.0
    # median of 5 with one huge outlier lies within the honest range
    assert bool(jnp.all(w <= jnp.max(state["params"]["w"][:4], 0) + 1e-6))


def test_latest_step_ignores_stray_entries(tmp_path):
    """Stray files, malformed step names, and .tmp leftovers must not break
    (or win) the latest-step scan."""
    d = str(tmp_path / "ckpt")
    ck.save(d, 3, make_state())
    # stray non-checkpoint content a killed job / operator might leave behind
    (tmp_path / "ckpt" / "README.txt").write_text("notes")
    (tmp_path / "ckpt" / "step_notanumber").mkdir()
    (tmp_path / "ckpt" / "step_00000009.tmp").mkdir()        # killed save
    (tmp_path / "ckpt" / "step_00000007").mkdir()            # no manifest
    assert ck.latest_step(d) == 3


def test_save_gcs_orphan_tmp_dirs(tmp_path):
    d = tmp_path / "ckpt"
    d.mkdir()
    orphan = d / "step_00000005.tmp"
    orphan.mkdir()
    (orphan / "junk.npy").write_bytes(b"\x00")
    ck.save(str(d), 6, make_state())
    assert not any(e.endswith(".tmp") for e in os.listdir(d))
    assert ck.latest_step(str(d)) == 6


def test_elastic_reshard(tmp_path):
    """Restore onto a different sharding (here: default single-device) —
    logical shapes are the contract, not device layout."""
    state = make_state()
    d = str(tmp_path / "ckpt")
    ck.save(d, 9, state)
    like = jax.eval_shape(lambda: state)
    restored, _ = ck.restore(d, 9, like, shardings=jax.tree.map(
        lambda _: None, like))
    np.testing.assert_array_equal(restored["params"]["b"],
                                  state["params"]["b"])
