"""Elastic membership on 8 forced host devices (subprocess — the device
count must be set before jax initialises): G 5 -> 4 -> 5 with real mesh
re-formation, plus the checkpointed kill-and-resume round trip."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_elastic_end_to_end():
    runner = os.path.join(os.path.dirname(__file__), "_elastic_runner.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, runner], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC_TESTS_PASS" in out.stdout, out.stdout
