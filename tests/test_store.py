"""benchmarks/store.py: the spec-hash-keyed result store (sweep cache /
regression tracker behind ``benchmarks/run.py --store``)."""
import json

from benchmarks import store


def entry(spec_hash="abc123", runner="fused", git_sha="deadbeef",
          acc=0.9, steps=(0.1, 0.5)):
    return {
        "experiment": {"name": "smoke", "runner": runner},
        "logs": [{"step": 10 * i, "acc": a} for i, a in enumerate(steps)],
        "final": {"acc": acc},
        "wall_s": 1.0,
        "provenance": {"spec_hash": spec_hash, "git_sha": git_sha},
    }


class TestStore:
    def test_append_then_dedupe(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        assert store.store(entry(), path) == ("appended", [])
        # identical rerun (timing may differ): deduped, store untouched
        dup = entry()
        dup["wall_s"] = 99.0
        assert store.store(dup, path) == ("duplicate", [])
        assert len(store.load(path)) == 1

    def test_drift_prints_diff_and_replaces(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        store.store(entry(acc=0.9), path)
        status, drift = store.store(entry(acc=0.7, steps=(0.1, 0.3)), path)
        assert status == "updated"
        assert any("final.acc" in line for line in drift)
        assert any("logs[1]" in line for line in drift)
        entries = store.load(path)
        assert len(entries) == 1 and entries[0]["final"]["acc"] == 0.7

    def test_key_is_spec_runner_sha(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        store.store(entry(), path)
        store.store(entry(runner="protocol"), path)
        store.store(entry(git_sha="0000000"), path)
        store.store(entry(spec_hash="other"), path)
        assert len(store.load(path)) == 4

    def test_jsonl_round_trip(self, tmp_path):
        path = str(tmp_path / "store.jsonl")
        store.store(entry(), path)
        with open(path) as fh:
            lines = [json.loads(l) for l in fh]
        assert lines[0]["provenance"]["spec_hash"] == "abc123"
        assert store.entry_key(lines[0]) == ("abc123", "fused", "deadbeef")
