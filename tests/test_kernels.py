"""Per-kernel allclose vs pure-jnp oracles, swept over shapes and dtypes
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gars
from repro.kernels.cwise_median import ops as cm_ops
from repro.kernels.cwise_median.ref import cwise_median_ref
from repro.kernels.mda_diameter import ops as md_ops
from repro.kernels.mda_diameter.ref import subset_diameters_ref
from repro.kernels.pairwise_sqdist import ops as pd_ops
from repro.kernels.pairwise_sqdist.ref import pairwise_sqdists_ref

SHAPES = [(5, 64), (9, 130), (16, 777), (12, 4096), (32, 257)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_gram_and_sqdist(n, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n * d), (n, d), dtype)
    got = pd_ops.pairwise_sqdists(x, interpret=True)
    want = pairwise_sqdists_ref(x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * d)


@pytest.mark.parametrize("n,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_cwise_median(n, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n + d), (n, d), dtype)
    got = cm_ops.cwise_median(x, interpret=True)
    want = cwise_median_ref(x)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("block_d", [128, 512, 2048])
def test_median_block_sweep(block_d):
    x = jax.random.normal(jax.random.PRNGKey(7), (11, 1000))
    got = cm_ops.cwise_median(x, block_d=block_d, interpret=True)
    np.testing.assert_allclose(got, cwise_median_ref(x), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,f", [(7, 2), (9, 2), (12, 3), (16, 5)])
def test_subset_diameters(n, f):
    x = jax.random.normal(jax.random.PRNGKey(n * f), (n, 50))
    d2 = pairwise_sqdists_ref(x)
    masks = jnp.asarray(gars.subset_masks(n, f))
    got = md_ops.subset_diameters(d2, masks, interpret=True)
    want = subset_diameters_ref(d2, masks)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,f,d", [(9, 2, 100), (7, 1, 31), (13, 4, 256)])
def test_full_mda_kernel_vs_gars(n, f, d):
    x = jax.random.normal(jax.random.PRNGKey(n + f + d), (n, d))
    got = md_ops.mda(x, f, interpret=True)
    want = gars.mda(x, f)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mda_kernel_excludes_outlier():
    x = jax.random.normal(jax.random.PRNGKey(0), (9, 64))
    x = x.at[8].set(1e5)
    out = md_ops.mda(x, 2, interpret=True)
    assert float(jnp.max(jnp.abs(out))) < 100.0
