"""Distributed ByzSGD protocol on 8 forced host devices (subprocess — the
device count must be set before jax initialises)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_protocol_end_to_end():
    runner = os.path.join(os.path.dirname(__file__), "_protocol_runner.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, runner], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PROTOCOL_TESTS_PASS" in out.stdout, out.stdout


@pytest.mark.slow
def test_exp_2d_mesh_oracle():
    """lm/tfm_tiny through the protocol runner on the full (rep=4, fsdp=2)
    mesh vs the same spec pinned to one device: final params must agree —
    2D sharding is a layout decision, never a semantics one."""
    runner = os.path.join(os.path.dirname(__file__), "_exp_2d_runner.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run([sys.executable, runner], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "EXP_2D_ORACLE_PASS" in out.stdout, out.stdout
