"""Unit + property tests for the GAR library (paper §3.1-3.2, Lemma 4.6)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import gars


def rand(n, d, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), (n, d))


class TestPairwise:
    def test_matches_bruteforce(self):
        x = rand(7, 33)
        d2 = gars.pairwise_sqdists(x)
        brute = jnp.asarray([[jnp.sum((x[i] - x[j]) ** 2) for j in range(7)]
                             for i in range(7)])
        np.testing.assert_allclose(d2, brute, rtol=1e-4, atol=1e-4)

    def test_gram_roundtrip(self):
        x = rand(5, 17)
        g = x @ x.T
        np.testing.assert_allclose(gars.sqdists_from_gram(g),
                                   gars.pairwise_sqdists(x), rtol=1e-4,
                                   atol=1e-4)


class TestMDA:
    def test_subset_count(self):
        assert gars.subset_masks(9, 2).shape == (36, 9)
        assert gars.n_subsets(16, 5) == 4368

    def test_excludes_outliers(self):
        x = rand(9, 20)
        x = x.at[7:].set(100.0)
        sel = gars.mda_selection(gars.pairwise_sqdists(x), 2)
        assert not bool(sel[7]) and not bool(sel[8])
        assert int(jnp.sum(sel)) == 7

    def test_greedy_vs_exact_clustered(self):
        # one tight cluster + far outliers: both must pick the cluster
        key = jax.random.PRNGKey(3)
        x = 0.01 * jax.random.normal(key, (10, 8))
        x = x.at[8].add(50.0).at[9].add(-50.0)
        d2 = gars.pairwise_sqdists(x)
        se = gars.mda_select_exact(d2, 2)
        sg = gars.mda_select_greedy(d2, 2)
        assert bool(jnp.all(se == sg))

    def test_lemma_4_6_bounded_deviation(self):
        """MDA output within the diameter of the correct set of one correct
        gradient (Lemma 4.6), under any Byzantine placement."""
        for seed in range(5):
            x = rand(9, 16, seed=seed)
            h = 7
            byz = 100.0 * rand(2, 16, seed=seed + 50)
            xs = jnp.concatenate([x[:h], byz])
            agg = gars.mda(xs, 2)
            diam = jnp.sqrt(jnp.max(gars.pairwise_sqdists(x[:h])))
            dmin = jnp.min(jnp.linalg.norm(x[:h] - agg, axis=1))
            assert float(dmin) <= float(diam) + 1e-4

    def test_f0_is_mean(self):
        x = rand(5, 9)
        np.testing.assert_allclose(gars.mda(x, 0), jnp.mean(x, 0), rtol=1e-6)


class TestMedianRules:
    def test_median_within_bounds(self):
        x = rand(9, 30)
        m = gars.coordinate_median(x)
        assert bool(jnp.all(m >= jnp.min(x, 0) - 1e-6))
        assert bool(jnp.all(m <= jnp.max(x, 0) + 1e-6))

    def test_masked_median_matches_subset(self):
        x = rand(9, 12)
        mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0, 1], bool)
        got = gars.masked_coordinate_median(x, mask)
        want = jnp.median(x[mask], axis=0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_masked_median_even_quorum(self):
        x = rand(8, 5)
        mask = jnp.asarray([1, 1, 1, 1, 0, 0, 0, 0], bool)
        np.testing.assert_allclose(gars.masked_coordinate_median(x, mask),
                                   jnp.median(x[:4], axis=0), rtol=1e-5,
                                   atol=1e-6)

    def test_trimmed_mean_and_meamed_resist(self):
        x = rand(9, 10)
        xs = x.at[8].set(1e5)
        for rule in (gars.trimmed_mean, gars.meamed):
            out = rule(xs, 1)
            assert float(jnp.max(jnp.abs(out))) < 100.0


class TestKrumFamily:
    def test_krum_picks_clustered(self):
        x = 0.1 * rand(9, 6)
        xs = x.at[8].set(1e4)
        out = gars.krum(xs, 2)
        assert float(jnp.max(jnp.abs(out))) < 10.0

    def test_multi_krum_and_bulyan(self):
        x = 0.1 * rand(9, 6)
        xs = x.at[8].set(1e4)
        assert float(jnp.max(jnp.abs(gars.multi_krum(xs, 2)))) < 10.0
        xs2 = 0.1 * rand(11, 6).at[10].set(1e4)
        assert float(jnp.max(jnp.abs(gars.bulyan(xs2, 2)))) < 10.0


class TestBounds:
    def test_thresholds(self):
        assert gars.mda_variance_threshold(18, 1) == pytest.approx(8.5)
        assert gars.mda_variance_threshold(18, 5) == pytest.approx(1.3)
        assert gars.krum_variance_threshold(18, 1) < gars.mda_variance_threshold(18, 1)
        assert gars.krum_variance_threshold(18, 0) == float("inf")


class TestTreeGar:
    def test_tree_mda_equals_flat(self):
        key = jax.random.PRNGKey(0)
        trees = []
        for i in range(7):
            k = jax.random.fold_in(key, i)
            trees.append({"a": jax.random.normal(k, (3, 4)),
                          "b": jax.random.normal(jax.random.fold_in(k, 1), (5,))})
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *trees)
        got = gars.tree_gar(gars.mda, stacked, 2)
        flat = jnp.stack([jnp.concatenate([t["a"].ravel(), t["b"]]) for t in trees])
        want = gars.mda(flat, 2)
        np.testing.assert_allclose(
            jnp.concatenate([got["a"].ravel(), got["b"]]), want, rtol=1e-4,
            atol=1e-5)


# --------------------------- property-based ---------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(5, 12), f=st.integers(1, 3), d=st.integers(1, 24),
       seed=st.integers(0, 10_000))
def test_prop_mda_in_convex_hull(n, f, d, seed):
    """MDA output is a convex combination of inputs => inside coordinate hull."""
    if n < 2 * f + 1:
        return
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    out = gars.mda(x, f)
    assert bool(jnp.all(out >= jnp.min(x, 0) - 1e-4))
    assert bool(jnp.all(out <= jnp.max(x, 0) + 1e-4))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 12), d=st.integers(1, 16), seed=st.integers(0, 10_000),
       q=st.integers(2, 12))
def test_prop_masked_median_safety(n, d, seed, q):
    """Lemma 4.2 ingredient: the masked median of any delivered subset lies
    within the per-coordinate range of the delivered values."""
    q = min(q, n)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, d))
    idx = jax.random.permutation(jax.random.fold_in(key, 1), n)[:q]
    mask = jnp.zeros((n,), bool).at[idx].set(True)
    m = gars.masked_coordinate_median(x, mask)
    sub = x[mask]
    assert bool(jnp.all(m >= jnp.min(sub, 0) - 1e-5))
    assert bool(jnp.all(m <= jnp.max(sub, 0) + 1e-5))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(5, 10), f=st.integers(1, 2), d=st.integers(2, 12),
       seed=st.integers(0, 1000), scale=st.floats(10.0, 1e4))
def test_prop_mda_bounded_by_honest(n, f, d, seed, scale):
    """No f Byzantine vectors can drag MDA beyond the honest diameter."""
    if n < 2 * f + 1:
        return
    key = jax.random.PRNGKey(seed)
    honest = jax.random.normal(key, (n - f, d))
    byz = scale * jnp.ones((f, d))
    out = gars.mda(jnp.concatenate([honest, byz]), f)
    centre = jnp.mean(honest, axis=0)
    diam = jnp.sqrt(jnp.max(gars.pairwise_sqdists(honest)))
    assert float(jnp.linalg.norm(out - centre)) <= 2.0 * float(diam) + 1e-3
