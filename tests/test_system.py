"""End-to-end behaviour tests for the paper's system.

Runs the real training driver (launch/train.py) as a subprocess on 8 forced
host devices: distributed ByzSGD protocol, checkpoint save, crash-restart
(elastic restore), and a Byzantine-worker run — the full production path.
"""
import os
import shutil
import subprocess
import sys

import pytest


def _run_driver(extra, ckpt_dir, timeout=1200):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "phi4-mini-3.8b", "--reduced", "--mesh", "4x2", "--groups", "4",
           "--T", "5", "--seq", "32", "--batch-per-group", "2",
           "--ckpt-dir", ckpt_dir, "--ckpt-every", "10",
           "--log-every", "5"] + extra
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)


@pytest.mark.slow
def test_train_checkpoint_restart(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    # phase 1: train 20 steps, checkpoints at 10 and 20
    out = _run_driver(["--steps", "20"], ckpt)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "done: 20 steps" in out.stdout
    losses = [float(l.split("loss")[1].split("(")[0])
              for l in out.stdout.splitlines() if "loss" in l]
    assert losses[-1] < losses[0], losses  # learning happened
    assert os.path.isdir(os.path.join(ckpt, "step_00000020"))
    # phase 2: "crash-restart" — same dir, more steps; must RESTORE not re-init
    out2 = _run_driver(["--steps", "30"], ckpt)
    assert out2.returncode == 0, out2.stderr[-3000:]
    assert "restored checkpoint at step 20" in out2.stdout


@pytest.mark.slow
def test_train_under_worker_attack(tmp_path):
    ckpt = str(tmp_path / "ckpt_byz")
    out = _run_driver(["--steps", "15", "--worker-attack", "alie",
                       "--n-byz", "1"], ckpt)
    assert out.returncode == 0, out.stderr[-3000:]
    losses = [float(l.split("loss")[1].split("(")[0])
              for l in out.stdout.splitlines() if "loss" in l]
    assert losses[-1] < losses[0] + 0.1, losses  # no divergence under ALIE
