"""Pallas flash-attention kernel vs the naive oracle: shapes x dtypes x
masking modes (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.PRNGKey(0)

CASES = [
    # B, S, H, kvH, hd, causal, window, qb, kb
    (2, 37, 4, 2, 16, True, 0, 8, 16),
    (1, 64, 4, 4, 32, True, 7, 16, 16),
    (2, 50, 6, 2, 64, False, 0, 16, 8),
    (1, 130, 8, 8, 128, True, 0, 64, 64),
    (3, 24, 2, 1, 8, True, 0, 8, 8),
]


@pytest.mark.parametrize("B,S,H,kvH,hd,causal,window,qb,kb", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, S, H, kvH, hd, causal, window, qb, kb, dtype):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, kvH, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, kvH, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, q_block=qb,
                          kv_block=kb, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=tol, atol=tol)


def test_flash_decode_shape():
    """Sq=1 against a long prefix (decode-style query)."""
    B, Skv, H, hd = 2, 96, 4, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (B, 1, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, Skv, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, Skv, H, hd))
    got = flash_attention(q, k, v, causal=True, q_block=8, kv_block=32,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)
