"""Pallas flash-attention kernel vs the naive oracle: shapes x dtypes x
masking modes (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

KEY = jax.random.PRNGKey(0)

CASES = [
    # B, S, H, kvH, hd, causal, window, qb, kb
    (2, 37, 4, 2, 16, True, 0, 8, 16),
    (1, 64, 4, 4, 32, True, 7, 16, 16),
    (2, 50, 6, 2, 64, False, 0, 16, 8),
    (1, 130, 8, 8, 128, True, 0, 64, 64),
    (3, 24, 2, 1, 8, True, 0, 8, 8),
]


@pytest.mark.parametrize("B,S,H,kvH,hd,causal,window,qb,kb", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(B, S, H, kvH, hd, causal, window, qb, kb, dtype):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, kvH, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, kvH, hd), dtype)
    got = flash_attention(q, k, v, causal=causal, window=window, q_block=qb,
                          kv_block=kb, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    tol = 3e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=tol, atol=tol)


def test_flash_decode_shape():
    """Sq=1 against a long prefix (decode-style query)."""
    B, Skv, H, hd = 2, 96, 4, 32
    q = jax.random.normal(jax.random.fold_in(KEY, 4), (B, 1, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (B, Skv, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (B, Skv, H, hd))
    got = flash_attention(q, k, v, causal=True, q_block=8, kv_block=32,
                          interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# gradient path: the custom_vjp backward kernels vs jax.grad of the oracle
# ---------------------------------------------------------------------------

GRAD_CASES = [
    # B, S, H, kvH, hd, causal, window, qb, kb — training shapes: causal,
    # GQA, sliding window, non-block-multiple lengths, a decoder-free case
    (2, 64, 4, 2, 32, True, 0, 64, 64),
    (1, 100, 4, 4, 32, True, 0, 32, 32),
    (2, 64, 4, 2, 32, True, 32, 64, 32),
    (2, 48, 4, 4, 16, False, 0, 16, 16),
]


def _grads(fn, q, k, v, do):
    def scalar(q, k, v):
        return jnp.vdot(fn(q, k, v).astype(jnp.float32),
                        do.astype(jnp.float32))

    return jax.grad(scalar, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("B,S,H,kvH,hd,causal,window,qb,kb", GRAD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_grads_match_ref(B, S, H, kvH, hd, causal, window, qb, kb,
                               dtype):
    """dq/dk/dv from the Pallas backward kernels match jax.grad through the
    naive reference — bf16 inputs ride f32 kernel accumulation, so the bf16
    tolerance is one rounding step, not a looser algorithm."""
    q = (0.5 * jax.random.normal(jax.random.fold_in(KEY, 7),
                                 (B, S, H, hd))).astype(dtype)
    k = (0.5 * jax.random.normal(jax.random.fold_in(KEY, 8),
                                 (B, S, kvH, hd))).astype(dtype)
    v = (0.5 * jax.random.normal(jax.random.fold_in(KEY, 9),
                                 (B, S, kvH, hd))).astype(dtype)
    do = (0.5 * jax.random.normal(jax.random.fold_in(KEY, 10),
                                  (B, S, H, hd))).astype(dtype)
    got = _grads(
        lambda q, k, v: flash_attention(q, k, v, causal=causal,
                                        window=window, q_block=qb,
                                        kv_block=kb, interpret=True),
        q, k, v, do)
    want = _grads(
        lambda q, k, v: attention_ref(q, k, v, causal=causal, window=window),
        q, k, v, do)
    tol = 3e-3 if dtype == jnp.float32 else 5e-2
    for name, g, w in zip("qkv", got, want):
        g = np.asarray(g, np.float32)
        w = np.asarray(w, np.float32)
        rel = np.max(np.abs(g - w)) / (np.max(np.abs(w)) + 1e-6)
        assert rel < tol, f"d{name}: rel err {rel:.2e} (tol {tol})"


def test_flash_grad_dtypes():
    """Gradients come back in the input dtype (bf16 in -> bf16 grads)."""
    B, S, H, hd = 1, 32, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd), jnp.bfloat16)
    k = jax.random.normal(KEY, (B, S, H, hd), jnp.bfloat16)
    v = jax.random.normal(KEY, (B, S, H, hd), jnp.bfloat16)
    dq, dk, dv = _grads(
        lambda q, k, v: flash_attention(q, k, v, interpret=True, q_block=16,
                                        kv_block=16),
        q, k, v, jnp.ones((B, S, H, hd), jnp.bfloat16))
    assert dq.dtype == dk.dtype == dv.dtype == jnp.bfloat16
