"""repro.exp: spec round-trips, hash stability, construction-time validation,
preset registry, and the one-spec-three-runners acceptance (stepwise == fused
params, netsim trace-driven run with provenance + accounting)."""
import json

import jax
import numpy as np
import pytest

import repro.exp as exp
from repro.core.attacks import ByzantineSpec
from repro.exp.spec import DATA, MODELS, SCHEDULES
from tests._hypothesis_compat import given, settings, st

SMALL = dict(n_workers=7, f_workers=2, n_servers=5, f_servers=1, T=5,
             steps=8, batch=8, model="mlp_h32", data="mixture5_small",
             metrics_every=4, eval_n=128)


def small(**kw):
    return exp.Experiment(**{**SMALL, **kw})


# ---------------------------------------------------------------------------
# serialization round trip + spec hash
# ---------------------------------------------------------------------------


class TestRoundTrip:
    def test_default_spec_round_trips(self):
        e = exp.Experiment()
        assert exp.Experiment.from_dict(e.to_dict()) == e

    def test_every_preset_round_trips_through_json(self):
        for name in exp.names():
            e = exp.get(name)
            blob = json.dumps(e.to_dict(), default=list)
            e2 = exp.Experiment.from_dict(json.loads(blob))
            assert e2 == e, name
            assert e2.spec_hash == e.spec_hash, name

    def test_attack_kwargs_survive_json(self):
        e = small(byz=ByzantineSpec(worker_attack="reversed", n_byz_workers=2,
                                    attack_kwargs=(("scale", 10.0),),
                                    equivocate=True))
        e2 = exp.Experiment.from_dict(json.loads(json.dumps(e.to_dict())))
        assert e2 == e and e2.byz.kwargs() == {"scale": 10.0}

    def test_unknown_field_rejected(self):
        d = exp.Experiment().to_dict()
        d["bogus"] = 1
        with pytest.raises(ValueError, match="unknown Experiment fields"):
            exp.Experiment.from_dict(d)

    def test_spec_hash_stable_across_field_order(self):
        d = small().to_dict()
        shuffled = dict(reversed(list(d.items())))
        assert exp.Experiment.from_dict(shuffled).spec_hash == \
            small().spec_hash

    def test_spec_hash_differs_on_any_field(self):
        assert small().spec_hash != small(gar="median").spec_hash
        assert small().spec_hash != small(seed=1).spec_hash

    @settings(max_examples=15)
    @given(n_extra=st.integers(0, 6), f_w=st.integers(0, 2),
           T=st.integers(1, 7), seed=st.integers(0, 10_000),
           lr0=st.floats(1e-4, 1.0))
    def test_random_valid_specs_round_trip(self, n_extra, f_w, T, seed, lr0):
        e = small(n_workers=3 * f_w + 1 + n_extra, f_workers=f_w, T=T,
                  seed=seed, lr0=lr0)
        e2 = exp.Experiment.from_dict(json.loads(json.dumps(e.to_dict())))
        assert e2 == e and e2.spec_hash == e.spec_hash


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------


class TestValidation:
    def test_cluster_preconditions_enforced(self):
        with pytest.raises(ValueError, match="3f_w\\+1"):
            small(n_workers=6, f_workers=2)
        with pytest.raises(ValueError, match="3f_ps\\+2"):
            small(n_servers=4, f_servers=1)

    @pytest.mark.parametrize("field,value,match", [
        ("runner", "warp", "unknown runner"),
        ("delivery", "psychic", "unknown delivery"),
        ("gar", "nope", "unknown aggregator"),
        ("model", "resnet9000", "unknown model"),
        ("data", "imagenet", "unknown data"),
        ("schedule", "cyclic", "unknown schedule"),
        ("scenario", "volcano", "unknown netsim scenario"),
        ("steps", 0, "steps must be"),
        ("agg_backend", "cuda", "unknown agg_backend"),
    ])
    def test_bad_fields_raise_at_construction(self, field, value, match):
        with pytest.raises((ValueError, KeyError), match=match):
            small(**{field: value})

    def test_bad_attack_names_raise(self):
        with pytest.raises(ValueError, match="unknown worker_attack"):
            small(byz=ByzantineSpec(worker_attack="meteor", n_byz_workers=1))
        with pytest.raises(ValueError, match="unknown server_attack"):
            small(byz=ByzantineSpec(server_attack="meteor", n_byz_servers=1))

    def test_trace_delivery_requires_scenario(self):
        with pytest.raises(ValueError, match="needs a netsim scenario"):
            small(delivery="trace")

    def test_decay_rejected_on_schedules_that_ignore_it(self):
        # a decay that the factory discards would fork spec_hash/provenance
        # without changing the run
        with pytest.raises(ValueError, match="ignores decay"):
            small(schedule="constant", decay=0.05)
        assert small(schedule="constant").schedule == "constant"  # default ok
        assert small(schedule="inverse_linear", decay=0.05).decay == 0.05

    def test_netsim_runner_normalizes_delivery(self):
        e = small(runner="netsim", scenario="baseline_uniform")
        assert e.delivery == "trace"

    def test_bulyan_rejected_for_pytree_roles(self):
        # tree_mode=None rules cannot be per-role GARs (ByzSGDConfig check)
        with pytest.raises(ValueError, match="pytree"):
            small(n_workers=12, f_workers=2, gar="bulyan")


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


class TestLowering:
    def test_to_config_round_trips(self):
        e = small(gar="median", pull_gar="meamed", variant="async")
        cfg = e.to_config()
        for k in ("n_workers", "f_workers", "n_servers", "f_servers", "T",
                  "gar", "pull_gar", "gather_gar", "worker_gar", "byz"):
            assert getattr(cfg, k) == getattr(e, k)

    def test_to_scenario_round_trips(self):
        e = small(scenario="heavy_tail_stragglers", seed=3)
        sc = e.to_scenario(model_d=500)
        assert (sc.n_workers, sc.f_workers, sc.T, sc.seed, sc.gar) == \
            (e.n_workers, e.f_workers, e.T, e.seed, e.gar)
        assert sc.model_d == 500

    def test_every_netsim_preset_lowers(self):
        for name in exp.names():
            e = exp.get(name)
            e.to_config()
            if e.scenario is not None:
                e.to_scenario(steps=5)

    def test_build_problem_and_schedule_resolve(self):
        e = small()
        init, loss, acc = e.build_problem()
        params = init(jax.random.PRNGKey(0))
        assert params["w0"].shape == (DATA[e.data].dim,
                                      MODELS[e.model]["hidden"])
        assert float(e.build_schedule()(0)) == pytest.approx(e.lr0)
        assert set(SCHEDULES) >= {"inverse_linear", "constant"}


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------


class TestPresets:
    def test_get_with_overrides_revalidates(self):
        assert exp.get("smoke", steps=3).steps == 3
        with pytest.raises(ValueError):
            exp.get("smoke", n_workers=3)

    def test_unknown_preset(self):
        with pytest.raises(KeyError, match="unknown experiment preset"):
            exp.get("does_not_exist")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            exp.register(exp.get("smoke"))

    def test_markdown_table_lists_all(self):
        table = exp.markdown_table()
        for name in exp.names():
            assert f"`{name}`" in table

    def test_models_table_lists_all(self):
        table = exp.models_table()
        for name in MODELS:
            assert f"`{name}`" in table

    def test_readme_tables_fresh(self):
        """Doc-drift gate: changing a runner, model, or preset must
        regenerate the README tables (`python -m repro.exp`)."""
        import os
        root = os.path.join(os.path.dirname(__file__), "..")
        with open(os.path.join(root, "README.md")) as f:
            readme = f.read()
        for table in (exp.runners_table(), exp.models_table(),
                      exp.markdown_table()):
            assert table in readme, (
                "README table stale — regenerate with "
                "`PYTHONPATH=src python -m repro.exp`:\n" + table)


# ---------------------------------------------------------------------------
# one spec, three runners (the acceptance criterion)
# ---------------------------------------------------------------------------


class TestRunners:
    def test_stepwise_equals_fused(self):
        e = exp.get("smoke", steps=7, metrics_every=1)
        a = exp.run(e, runner="stepwise")
        b = exp.run(e, runner="fused")
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-5, atol=2e-6)
        np.testing.assert_allclose([m["acc"] for m in a.logs],
                                   [m["acc"] for m in b.logs],
                                   rtol=1e-5, atol=1e-6)
        assert a.final["acc"] == pytest.approx(b.final["acc"], abs=1e-5)

    def test_protocol_equals_fused(self):
        # the acceptance criterion: the SAME spec through the distributed
        # protocol on a 1-group/1-device mesh matches the fused runner
        e = exp.get("smoke", steps=7, metrics_every=1)
        a = exp.run(e, runner="fused")
        b = exp.run(e, runner="protocol")
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-5, atol=2e-6)
        assert [m["acc"] for m in a.logs] == [m["acc"] for m in b.logs]
        assert a.final["acc"] == pytest.approx(b.final["acc"], abs=1e-5)
        assert b.provenance["mesh"] == {"rep": 1, "fsdp": 1, "model": 1}
        assert b.provenance["protocol_engine"] == "sharded"

    def test_protocol_requires_square_cluster(self):
        with pytest.raises(ValueError, match="n_workers == n_servers"):
            small(runner="protocol")  # SMALL is 7 workers / 5 servers

    def test_protocol_engine_knob_validated(self):
        with pytest.raises(ValueError, match="unknown protocol_engine"):
            small(protocol_engine="warp")
        e = exp.get("smoke", runner="protocol", protocol_engine="naive")
        assert e.to_protocol_config().engine == "naive"

    def test_netsim_runner_attaches_accounting(self):
        res = exp.run("smoke", runner="netsim", steps=6)
        assert res.netsim is not None
        assert res.netsim["scenario"] == "baseline_uniform"
        assert res.netsim["virtual_ms"] > 0
        assert "totals" in res.netsim

    def test_trace_stepwise_equals_trace_fused(self):
        e = exp.get("smoke", steps=6, delivery="trace")
        a = exp.run(e, runner="stepwise")
        b = exp.run(e, runner="netsim")
        for x, y in zip(jax.tree.leaves(a.state.params),
                        jax.tree.leaves(b.state.params)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=2e-5, atol=2e-6)

    def test_result_serializes_with_provenance(self):
        res = exp.run("smoke", steps=4)
        d = json.loads(json.dumps(res.to_dict(), default=float))
        assert d["experiment"]["name"] == "smoke"
        prov = d["provenance"]
        assert prov["spec_hash"] == res.experiment.spec_hash
        assert set(prov) >= {"spec_hash", "git_sha", "jax_version", "device"}
        assert d["final"]["acc"] == pytest.approx(res.final["acc"])

    def test_overrides_on_run(self):
        res = exp.run("smoke", steps=4, metrics_every=2)
        assert res.experiment.steps == 4
        assert [m["step"] for m in res.logs] == [0, 2]

    def test_write_result(self, tmp_path):
        res = exp.run("smoke", steps=4)
        path = exp.write_result(res, out_dir=str(tmp_path))
        with open(path) as fh:
            assert json.load(fh)["provenance"]["spec_hash"] == \
                res.experiment.spec_hash


# ---------------------------------------------------------------------------
# netsim integration satellites
# ---------------------------------------------------------------------------


class TestNetsimSatellites:
    def test_scenarios_get_warns_but_works(self):
        from repro.netsim import scenarios
        with pytest.warns(DeprecationWarning, match="deprecated"):
            sc = scenarios.get("baseline_uniform", steps=5)
        assert sc.steps == 5

    def test_measured_compute_reads_committed_baseline(self):
        import json as _json
        import os
        from repro.netsim import scenarios
        root = os.path.join(os.path.dirname(__file__), os.pardir)
        path = os.path.join(root, "BENCH_throughput.json")
        ct = scenarios.measured_compute("mlp_h64", "async", path=path)
        sps = _json.load(open(path))["lanes"]["async/mlp_h64"]["fused"][
            "steps_per_s"]
        assert ct.mean_ms == pytest.approx(1000.0 / sps)

    def test_measured_compute_unknown_lane(self):
        from repro.netsim import scenarios
        with pytest.raises((KeyError, FileNotFoundError)):
            scenarios.measured_compute("mlp_h9999", "async")

    def test_sync_variant_scenario_shapes(self):
        from repro.netsim import ClusterSim, scenarios
        sc = scenarios.build("baseline_uniform", variant="sync", n_workers=5,
                             f_workers=1, steps=6)
        assert sc.pull_need == 1 and sc.push_need == 1
        t = ClusterSim(sc).run()
        assert t.pull_idx.shape == (6, 5, 1)
        assert t.push_idx.shape == (6, 5, 1)
        assert t.shortfalls == 0
        # round-robin pull: worker w at step k accepts server (w + k) % n_ps
        for k in range(6):
            for w in range(5):
                assert t.pull_idx[k, w, 0] == (w + k) % sc.n_servers
        # round-robin reply pair: server s consumed exactly the gradient of
        # its exchange partner w = (s - k) % n_ps (no broadcast pushes)
        for k in range(6):
            for s in range(sc.n_servers):
                assert t.push_idx[k, s, 0] == (s - k) % sc.n_servers

    def test_sync_push_schedule_uneven_workers(self):
        # n_w = 9, n_ps = 5: rows are ceil(9/5) = 2 wide; server s at step k
        # waits only for its scheduled congruence class w ≡ s - k (mod 5)
        from repro.netsim import ClusterSim, scenarios
        sc = scenarios.build("baseline_uniform", variant="sync", n_workers=9,
                             f_workers=1, steps=4)
        assert sc.push_need == 2
        for k in range(4):
            for s in range(5):
                r = (s - k) % 5
                assert sc.push_scheduled(s, k) == (2 if r <= 3 else 1)
        t = ClusterSim(sc).run()
        assert t.shortfalls == 0
        assert t.push_idx.shape == (4, 5, 2)
        for k in range(4):
            for s in range(5):
                r = (s - k) % 5
                scheduled = {w for w in range(9) if w % 5 == r}
                assert set(t.push_idx[k, s].tolist()) <= scheduled
        # per-step sync bytes: each worker sends exactly ONE gradient
        tot = t.ledger.totals()
        D = sc.model_d * sc.dtype_bytes
        assert tot["push"]["tx_bytes"] == 9 * 4 * D

    def test_sync_push_pads_stay_in_scheduled_class(self):
        # a permanently-dead worker starves its round-robin servers: the
        # forced-close pads must still name workers from the scheduled
        # congruence class w ≡ s - k (mod n_ps), never an unscheduled worker
        from repro.netsim import ClusterSim, scenarios
        from repro.netsim.faults import CrashPlan, CrashWindow, FaultPlan
        sc = scenarios.build(
            "baseline_uniform", variant="sync", n_workers=9, f_workers=1,
            steps=4, update_ms=0.1,
            faults=FaultPlan(crashes=CrashPlan((
                CrashWindow(node=5, t_down=0.0, t_up=float("inf")),))))
        t = ClusterSim(sc).run()
        assert t.shortfalls > 0
        for k in range(sc.steps):
            for s in range(sc.n_servers):
                r = (s - k) % sc.n_servers
                scheduled = {w for w in range(sc.n_workers)
                             if w % sc.n_servers == r}
                assert set(t.push_idx[k, s].tolist()) <= scheduled, (k, s)

    def test_sync_closed_zero_row_not_refilled_as_shortfall(self):
        """A sync pull row recording server 0 is a legitimately closed
        quorum; a worker dying mid-compute afterwards must not make the
        dead-row fill re-pad it (or count it as a shortfall)."""
        from repro.netsim import ClusterSim, scenarios
        from repro.netsim.faults import CrashPlan, CrashWindow, FaultPlan
        # worker 0 (node id 5) pulls from server (0+0)%5 = 0 at step 0, then
        # crashes during its gradient computation and never recovers
        sc = scenarios.build(
            "baseline_uniform", variant="sync", n_workers=5, f_workers=1,
            steps=4, update_ms=0.1,
            faults=FaultPlan(crashes=CrashPlan((
                CrashWindow(node=5, t_down=1.5, t_up=float("inf")),))))
        cs = ClusterSim(sc)
        t = cs.run()
        assert cs.pull_closed[0, 0]          # the [0] row was a real quorum
        assert t.pull_idx[0, 0, 0] == 0
        # the fill only padded the dead worker's NEVER-closed rows, each
        # named after the round-robin server of that step
        for k in range(1, sc.steps):
            assert not cs.pull_closed[k, 0]
            assert t.pull_idx[k, 0, 0] == k % sc.n_servers
