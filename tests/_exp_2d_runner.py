"""Subprocess body for the 2D-mesh experiment oracle (needs 8 forced
devices, which must be set before jax initialises — hence not in-process).

Trains the ``lm/tfm_tiny`` transformer preset through the protocol runner on
the full 8-device fleet — where ``make_protocol_mesh`` lights up
``(rep=4, fsdp=2, model=1)`` — then re-runs the identical spec pinned to a
single device ``(1, 1, 1)`` and asserts the final replica-stacked parameters
agree. Sharding must be a layout decision, not a semantics one: fsdp>1 only
changes where parameter shards live, never what the protocol computes.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import exp  # noqa: E402
from repro.exp import runners  # noqa: E402
from repro.launch.mesh import make_protocol_mesh  # noqa: E402


def main():
    assert jax.device_count() == 8

    res8 = exp.run("lm/tfm_tiny")
    assert res8.provenance["mesh"] == {"rep": 4, "fsdp": 2, "model": 1}, \
        res8.provenance["mesh"]
    assert all(np.isfinite(m["acc"]) for m in res8.logs), res8.logs
    assert res8.final["acc"] > res8.logs[0]["acc"], (
        "no training progress", res8.logs, res8.final)
    p8 = jax.tree.map(np.asarray, jax.device_get(res8.state.params))
    print(f"8-device (4,2,1): acc {res8.logs[0]['acc']:.3f} -> "
          f"{res8.final['acc']:.3f}")

    # same spec, single device: (1, 1, 1) — the sharding oracle
    runners._protocol_mesh = lambda G: make_protocol_mesh(
        G, devices=jax.devices()[:1])
    res1 = exp.run("lm/tfm_tiny")
    assert res1.provenance["mesh"] == {"rep": 1, "fsdp": 1, "model": 1}, \
        res1.provenance["mesh"]
    p1 = jax.tree.map(np.asarray, jax.device_get(res1.state.params))
    print(f"1-device (1,1,1): acc {res1.logs[0]['acc']:.3f} -> "
          f"{res1.final['acc']:.3f}")

    # bf16 activations => reduction order differs across layouts, so a few
    # coordinates drift by O(bf16 eps) per step; gate on relative L2 per
    # leaf (layout-stable) with a loose max-norm backstop
    worst_l2, worst_max = 0.0, 0.0
    for l8, l1 in zip(jax.tree.leaves(p8), jax.tree.leaves(p1)):
        assert l8.shape == l1.shape
        d = l8.astype(np.float32) - l1.astype(np.float32)
        ref = l1.astype(np.float32)
        worst_l2 = max(worst_l2, float(np.linalg.norm(d))
                       / (float(np.linalg.norm(ref)) + 1e-6))
        worst_max = max(worst_max, float(np.max(np.abs(d)))
                        / (float(np.max(np.abs(ref))) + 1e-6))
    print(f"param divergence (8-dev vs 1-dev): "
          f"rel-L2 {worst_l2:.2e}, rel-max {worst_max:.2e}")
    assert worst_l2 < 2e-2, worst_l2
    assert worst_max < 1e-1, worst_max
    print("EXP_2D_ORACLE_PASS")


if __name__ == "__main__":
    main()
