"""quorum sampling, attack injection, filter math (unit + property)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import filters, quorum
from repro.core.attacks import (ByzantineSpec, alie_zmax, inject_gradients,
                                inject_models)

KEY = jax.random.PRNGKey(0)


class TestQuorum:
    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(3, 20), q=st.integers(1, 20), seed=st.integers(0, 99))
    def test_mask_cardinality(self, n, q, seed):
        q = min(q, n)
        m = quorum.sample_quorum_mask(jax.random.PRNGKey(seed), n, q)
        assert int(jnp.sum(m)) == q

    def test_include_self(self):
        masks = quorum.receiver_quorum_masks(KEY, 6, 6, 3, include_self=True)
        assert bool(jnp.all(jnp.diagonal(masks)))
        assert bool(jnp.all(jnp.sum(masks, 1) == 3))

    def test_indices_unique(self):
        idx = quorum.receiver_quorum_indices(KEY, 5, 9, 6)
        for row in idx:
            assert len(set(row.tolist())) == 6

    def test_validate_counts(self):
        quorum.validate_counts(9, 2, 5, 1, 7, 4)
        with pytest.raises(ValueError):
            quorum.validate_counts(6, 2, 5, 1, 4, 4)


class TestAttacks:
    def _stack(self, n=7):
        return {"w": jax.random.normal(KEY, (n, 4, 3)),
                "b": jax.random.normal(jax.random.fold_in(KEY, 1), (n, 5))}

    def test_honest_prefix_untouched(self):
        g = self._stack()
        spec = ByzantineSpec(worker_attack="reversed", n_byz_workers=2)
        out = inject_gradients(g, spec, KEY)
        np.testing.assert_array_equal(out["w"][:5], g["w"][:5])
        assert not np.allclose(out["w"][5:], g["w"][5:])

    def test_equivocation_distinct_per_receiver(self):
        g = self._stack()
        spec = ByzantineSpec(worker_attack="random", n_byz_workers=1,
                             equivocate=True)
        out = inject_gradients(g, spec, KEY, n_receivers=3)
        assert out["w"].shape == (3, 7, 4, 3)
        assert not np.allclose(out["w"][0, 6], out["w"][1, 6])
        np.testing.assert_array_equal(out["w"][0, :6], out["w"][1, :6])

    def test_model_attacks(self):
        m = self._stack(5)
        for atk in ("reversed", "partial_drop", "random", "lie"):
            spec = ByzantineSpec(server_attack=atk, n_byz_servers=1)
            out = inject_models(m, spec, KEY)
            assert jax.tree.all(jax.tree.map(
                lambda l: bool(jnp.all(jnp.isfinite(l))), out))

    def test_alie_zmax_reasonable(self):
        assert 0.0 < alie_zmax(24, 5) < 3.0

    def test_no_attack_passthrough(self):
        g = self._stack()
        out = inject_gradients(g, ByzantineSpec(), KEY)
        assert out is g


class TestFilters:
    def test_lipschitz_history_quantile(self):
        h = filters.LipschitzHistory.create(8)
        for v in [1.0, 1.1, 0.9, 1.05]:
            h = h.push(jnp.float32(v))
        ok = filters.lipschitz_pass(jnp.float32(1.0), h, n_ps=4, f_ps=1)
        bad = filters.lipschitz_pass(jnp.float32(50.0), h, n_ps=4, f_ps=1)
        assert bool(ok) and not bool(bad)

    def test_empty_history_accepts(self):
        h = filters.LipschitzHistory.create(8)
        assert bool(filters.lipschitz_pass(jnp.float32(1e9), h, 4, 1))

    def test_outliers_bound_grows_within_phase(self):
        b1 = filters.outliers_bound(jnp.int32(1), 10, jnp.float32(0.1),
                                    jnp.float32(1.0), 9, 2)
        b2 = filters.outliers_bound(jnp.int32(9), 10, jnp.float32(0.1),
                                    jnp.float32(1.0), 9, 2)
        assert float(b2) > float(b1)

    def test_outliers_pass(self):
        a = {"w": jnp.zeros((3,))}
        b = {"w": jnp.ones((3,))}
        assert bool(filters.outliers_pass(a, a, jnp.float32(0.1)))
        assert not bool(filters.outliers_pass(a, b, jnp.float32(0.1)))

    def test_safe_T(self):
        assert filters.safe_T(2.0, 0.05) == int(1 / (3 * 2.0 * 0.05))
