"""Per-architecture smoke + consistency tests (reduced configs).

For every assigned arch: one forward/train step asserting shapes + finite
values, gradient finiteness, and a prefill/decode CONSISTENCY check: chained
decode logits must match a fresh prefill of the extended prefix (exercises
every cache type: KV, SSM state, WKV state, hybrid, enc-dec cross)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import ARCH_IDS, get_bundle

KEY = jax.random.PRNGKey(7)
B, S = 2, 32


@pytest.fixture(scope="module", params=ARCH_IDS)
def bundle(request):
    return get_bundle(request.param, reduced=True)


@pytest.fixture(scope="module")
def params(bundle):
    return bundle.init(jax.random.fold_in(KEY, 1))


class TestSmoke:
    def test_loss_and_grads_finite(self, bundle, params):
        batch = bundle.make_batch("train", B, S, jax.random.fold_in(KEY, 2))
        loss, grads = jax.value_and_grad(bundle.loss)(params, batch)
        assert jnp.isfinite(loss), bundle.cfg.name
        assert 1.0 < float(loss) < 20.0, (bundle.cfg.name, float(loss))
        for leaf in jax.tree.leaves(grads):
            assert bool(jnp.all(jnp.isfinite(leaf))), bundle.cfg.name

    def test_prefill_decode_shapes(self, bundle, params):
        caches = bundle.init_caches(B, max_len=S + 8, n_chunks=4)
        pf = bundle.make_batch("prefill", B, S, jax.random.fold_in(KEY, 3))
        logits, caches = bundle.prefill(params, pf, caches)
        assert logits.shape == (B, bundle.cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        dec = bundle.make_batch("decode", B, S, jax.random.fold_in(KEY, 4))
        logits2, _ = bundle.decode(params, caches, dec)
        assert logits2.shape == (B, bundle.cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits2)))


def _extend_batch(bundle, pf_batch, extra_tok, n):
    """Prefill batch for prefix + n extra decode tokens."""
    out = dict(pf_batch)
    if "tokens" in out:
        out["tokens"] = jnp.concatenate([out["tokens"]] + [extra_tok] * n, 1)
    if "embeds" in out:
        emb = out["embeds"]
        out["embeds"] = jnp.concatenate([emb] + [emb[:, -1:]] * n, 1)
    if "positions" in out and out["positions"].ndim == 3:
        p = out["positions"]
        last = p[:, :, -1:]
        steps = [last + i + 1 for i in range(n)]
        out["positions"] = jnp.concatenate([p] + steps, 2)
    if "labels" in out:
        del out["labels"]
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    """logits(decode chain) == logits(fresh prefill of the longer prefix)."""
    bundle = get_bundle(arch, reduced=True)
    params = bundle.init(jax.random.fold_in(KEY, 10))
    S0, n_dec = 12, 3
    pf = bundle.make_batch("prefill", B, S0, jax.random.fold_in(KEY, 11))
    caches = bundle.init_caches(B, max_len=S0 + n_dec + 1, n_chunks=4,
                                dtype=jnp.float32)
    logits, caches = bundle.prefill(params, pf, caches)

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(n_dec):
        dec = {"token": tok}
        if bundle.cfg.family == "vlm":
            emb = pf["embeds"][:, -1:]
            pos = pf["positions"][:, :, -1:] + i + 1
            dec = {"embeds": emb, "positions": pos}
        logits, caches = bundle.decode(params, caches, dec)
        # oracle: fresh prefill over prefix + decoded tokens
        ext = _extend_batch(bundle, pf, tok, i + 1)
        oracle_caches = bundle.init_caches(B, max_len=S0 + n_dec + 1,
                                           n_chunks=4, dtype=jnp.float32)
        want, _ = bundle.prefill(params, ext, oracle_caches)
        np.testing.assert_allclose(
            jax.nn.log_softmax(logits), jax.nn.log_softmax(want),
            rtol=5e-2, atol=5e-2, err_msg=f"{arch} step {i}")


def test_sliding_window_active():
    """h2o-danube SWA: distant tokens must not influence decode logits."""
    bundle = get_bundle("h2o-danube-3-4b", reduced=True, sliding_window=8)
    params = bundle.init(jax.random.fold_in(KEY, 20))
    S0 = 24
    pf = bundle.make_batch("prefill", 1, S0, jax.random.fold_in(KEY, 21))
    # two prefixes differing ONLY in the first token (outside the window)
    toks_a = pf["tokens"]
    toks_b = toks_a.at[:, 0].set((toks_a[:, 0] + 1) % bundle.cfg.vocab)
    outs = []
    for toks in (toks_a, toks_b):
        caches = bundle.init_caches(1, max_len=S0 + 2, n_chunks=4,
                                    dtype=jnp.float32)
        logits, _ = bundle.prefill(params, {"tokens": toks}, caches)
        outs.append(logits)
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-4)


def test_configs_match_assignment():
    """The exact assigned hyperparameters."""
    import repro.configs.dbrx_132b as c1
    import repro.configs.qwen3_moe_235b_a22b as c2
    import repro.configs.rwkv6_3b as c3
    import repro.configs.whisper_small as c4
    a = c1.CONFIG
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab, a.n_experts, a.top_k) == (40, 6144, 48, 8, 10752,
                                               100352, 16, 4)
    b = c2.CONFIG
    assert (b.n_layers, b.d_model, b.n_heads, b.n_kv_heads, b.d_ff,
            b.vocab, b.n_experts, b.top_k) == (94, 4096, 64, 4, 1536,
                                               151936, 128, 8)
    assert (c3.CONFIG.n_layers, c3.CONFIG.d_model, c3.CONFIG.d_ff,
            c3.CONFIG.vocab) == (32, 2560, 8960, 65536)
    assert (c4.CONFIG.n_layers, c4.CONFIG.encoder_layers, c4.CONFIG.d_model,
            c4.CONFIG.n_heads, c4.CONFIG.d_ff, c4.CONFIG.vocab) == (
        12, 12, 768, 12, 3072, 51865)
