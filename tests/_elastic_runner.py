"""Subprocess body for the distributed elastic-membership test (needs 8
forced devices, which must be set before jax initialises — hence not
in-process).

Drives the full elastic cycle on a real multi-device mesh: G=5 launch
(rep=5 over 8 devices) -> one group leaves (re-formed rep=4 mesh) ->
recovers (back to rep=5, re-seeded from the DMC median), then the
kill-and-resume round trip: a checkpointed run killed mid-shrunk-epoch
must resume at G'=4 and finish bit-identical to the uninterrupted run."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import shutil  # noqa: E402
import tempfile  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro.exp as exp  # noqa: E402
from repro.checkpoint import checkpointer as ck  # noqa: E402


def _assert_trees_equal(a, b, msg):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb), msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


def main():
    assert jax.device_count() == 8, jax.device_count()

    # uninterrupted churn run: G 5 -> 4 -> 5 across real device meshes
    oracle = exp.run("elastic/planned_churn")
    mem = oracle.provenance["membership"]
    assert [len(ep["active"]) for ep in mem["epochs"]] == [5, 4, 5], mem
    assert oracle.provenance["mesh"]["rep"] == 5   # relaunched at full width
    assert oracle.final["acc"] >= 0.9, oracle.final
    print(f"churn 5->4->5: final acc {oracle.final['acc']:.3f} OK")

    d = tempfile.mkdtemp()
    try:
        # checkpoint-emitting run must match the no-checkpoint oracle
        full = exp.run("elastic/planned_churn", ckpt_dir=d, ckpt_every=4)
        _assert_trees_equal(oracle.state.params, full.state.params,
                            "ckpt-emitting run diverged from oracle")

        # kill after step 12 (inside the G'=4 epoch), resume, re-finish
        for name in sorted(os.listdir(d)):
            if int(name.split("_")[-1]) > 12:
                shutil.rmtree(os.path.join(d, name))
        meta = ck.read_manifest(d, 12)["meta"]
        assert meta["active"] == [0, 1, 2, 3], meta   # shrunk-fleet ckpt
        resumed = exp.run("elastic/planned_churn", ckpt_dir=d, ckpt_every=4)
        assert resumed.provenance["membership"]["resumed_at"] == 12
        _assert_trees_equal(oracle.state.params, resumed.state.params,
                            "resume-at-G'=4 diverged from oracle")
        assert resumed.final == oracle.final
        print("kill-and-resume at G'=4: bit-identical OK")
    finally:
        shutil.rmtree(d, ignore_errors=True)

    print("ELASTIC_TESTS_PASS")


if __name__ == "__main__":
    main()
