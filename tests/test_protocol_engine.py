"""ProtocolEngine: the single-host engine as the distributed protocol's oracle.

With a G = n_workers = n_servers cluster, the protocol's scatter/gather steps
draw the same quorums as ``ByzSGDSimulator`` (same key chain, pluggable
``DeliveryModel``), so on a 1-group/1-device mesh the fused protocol epochs
must reproduce the fused single-host engine: params allclose (the collective
formulation aggregates as masked rules / Gram-weighted sums, so float
summation order differs), accuracy buffers identical, diameters allclose at a
looser tolerance (a max-minus-min of nearly-identical replicas amplifies the
last-ulp noise). Mirrors ``tests/test_engine.py``'s gather off-by-one,
chunking, and ``TraceDelivery`` (realized netsim quorums + trace wrap) cases.
"""
import jax
import numpy as np
import pytest

from repro.configs.paper_models import make_mlp_problem
from repro.core import protocol
from repro.core.engine import EpochEngine
from repro.core.simulator import ByzSGDConfig, ByzSGDSimulator
from repro.data.pipeline import DeviceBatchStream, MixtureSpec
from repro.launch.mesh import make_protocol_mesh, use_mesh
from repro.optim.schedules import inverse_linear

MIX = MixtureSpec(n_classes=5, dim=16, sep=2.5)
BATCH = 8
G = 5


def make_cfg(T=5):
    return ByzSGDConfig(n_workers=G, f_workers=1, n_servers=G, f_servers=1,
                        T=T)


def make_pcfg(cfg, engine="sharded"):
    return protocol.ProtocolConfig.derive(
        G, T=cfg.T, engine=engine, f_workers=cfg.f_workers,
        f_servers=cfg.f_servers, q_workers=cfg.q_workers,
        q_servers=cfg.q_servers)


def problem():
    return make_mlp_problem(dim=MIX.dim, hidden=32, n_classes=MIX.n_classes)


def eval_pair():
    return DeviceBatchStream(0, MIX, G, BATCH).eval_set(256)


def fused_run(cfg, steps, eval_set, delivery=None):
    init, loss, acc = problem()
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.01),
                          delivery=delivery)
    eng = EpochEngine(sim, acc_fn=acc, eval_set=eval_set, track_delta=True)
    state = sim.init_state(jax.random.PRNGKey(0))
    return eng.run(state, stream=DeviceBatchStream(0, MIX, G, BATCH),
                   steps=steps)


def protocol_run(cfg, steps, eval_set, delivery=None, engine="sharded",
                 mesh=None, epoch_steps=None):
    init, loss, acc = problem()
    pcfg = make_pcfg(cfg, engine)
    bundle = protocol.ProblemBundle(init=init, loss=loss)
    eng = protocol.ProtocolEngine(bundle, pcfg, inverse_linear(0.05, 0.01),
                                  mesh=mesh, delivery=delivery, acc_fn=acc,
                                  eval_set=eval_set, track_delta=True)
    state = eng.init_state(jax.random.PRNGKey(0))
    return eng.run(state, stream=DeviceBatchStream(0, MIX, G, BATCH),
                   steps=steps, epoch_steps=epoch_steps)


def assert_oracle(steps, delivery_fn=None, engine="sharded", mesh=None,
                  epoch_steps=None):
    ev = eval_pair()
    s_ref, ref = fused_run(make_cfg(), steps, ev,
                           delivery_fn() if delivery_fn else None)
    s_pro, pro = protocol_run(make_cfg(), steps, ev,
                              delivery_fn() if delivery_fn else None,
                              engine=engine, mesh=mesh,
                              epoch_steps=epoch_steps)
    for a, b in zip(jax.tree.leaves(s_ref.params),
                    jax.tree.leaves(s_pro.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=2e-6)
    assert int(s_pro.t) == steps
    np.testing.assert_allclose(ref["acc"], pro["acc"], rtol=1e-5, atol=1e-6)
    # diameters are max-minus-min over nearly-identical replicas: the ~1e-7
    # per-step aggregation noise is relatively amplified there, especially
    # right after a gather collapses the spread to ~1e-2
    np.testing.assert_allclose(ref["delta"], pro["delta"],
                               rtol=5e-2, atol=5e-3)
    np.testing.assert_allclose(ref["l2_diam"], pro["l2_diam"],
                               rtol=5e-2, atol=5e-3)
    return ref, pro


class TestOracleEquivalence:
    def test_partial_tail_epoch(self):
        # 12 = 2 full T=5 epochs (gathers after steps 5 and 10) + 2 tail steps
        ref, pro = assert_oracle(steps=12)
        np.testing.assert_array_equal(ref["acc"], pro["acc"])  # identical

    def test_exact_epoch_boundary(self):
        # the DMC gather fires after the LAST step: t % T == 0 at t = T
        assert_oracle(steps=5)

    def test_one_step_past_boundary(self):
        assert_oracle(steps=6)

    def test_chunking_does_not_change_results(self):
        # scan chunk length is free: the boundary rides on the carried t
        assert_oracle(steps=12, epoch_steps=7)

    def test_naive_collective_engine(self):
        assert_oracle(steps=12, engine="naive")

    def test_one_device_mesh(self):
        # the acceptance path: protocol on a ('rep','fsdp','model') mesh over
        # the available devices (1-device here) still matches the oracle
        mesh = make_protocol_mesh(G)
        assert mesh.devices.shape == (1, 1, 1)
        with use_mesh(mesh):
            assert_oracle(steps=12, mesh=mesh)

    def test_stepwise_loop_is_also_the_oracle(self):
        # protocol == fused == stepwise: close the triangle via the per-step
        # reference loop
        ev = eval_pair()
        init, loss, acc = problem()
        cfg = make_cfg()
        sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.01))
        state = sim.init_state(jax.random.PRNGKey(0))
        from repro.data.pipeline import classification_stream
        stream, _ = classification_stream(0, MIX, G, BATCH, 12)
        state, _ = sim.run(state, stream)
        s_pro, _ = protocol_run(cfg, 12, ev)
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(s_pro.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=2e-6)


def trace_delivery():
    from repro.netsim import ClusterSim, scenarios
    sc = scenarios.build("heavy_tail_stragglers", n_workers=G, f_workers=1,
                         n_servers=G, f_servers=1, T=5, steps=10,
                         model_d=1000)
    trace = ClusterSim(sc).run()
    # masked delivery collapses duplicate sender ids; the realized quorums of
    # a shortfall-free run are duplicate-free, which is what makes the
    # masked-protocol and subset-simulator paths aggregate the same stacks
    assert trace.shortfalls == 0
    return trace.to_delivery()


class TestTraceDelivery:
    def test_protocol_on_realized_quorums_matches_fused(self):
        assert_oracle(steps=10, delivery_fn=trace_delivery)

    def test_run_past_trace_length_wraps(self):
        # trace has 10 steps; a 14-step run wraps (t mod trace length) in
        # both paths, crossing a gather boundary on the wrapped counter
        assert_oracle(steps=14, delivery_fn=trace_delivery)

    def test_gather_round_indexing(self):
        # steps == 2T exactly: the second gather reads trace round r=1, the
        # off-by-one mirrored from tests/test_engine.py
        assert_oracle(steps=10, delivery_fn=trace_delivery, epoch_steps=4)


class TestEngineMechanics:
    def test_compile_cache_shared_across_instances(self):
        init, loss, _ = problem()
        pcfg = make_pcfg(make_cfg())
        bundle = protocol.ProblemBundle(init=init, loss=loss)
        a = protocol.ProtocolEngine(bundle, pcfg, inverse_linear(0.05, 0.01))
        init2, loss2, _ = problem()  # fresh partials, same semantics
        b = protocol.ProtocolEngine(
            protocol.ProblemBundle(init=init2, loss=loss2), pcfg,
            inverse_linear(0.05, 0.01))
        assert a._epoch is b._epoch

    def test_engines_key_separately(self):
        init, loss, _ = problem()
        bundle = protocol.ProblemBundle(init=init, loss=loss)
        a = protocol.ProtocolEngine(bundle, make_pcfg(make_cfg(), "sharded"),
                                    inverse_linear(0.05, 0.01))
        b = protocol.ProtocolEngine(bundle, make_pcfg(make_cfg(), "naive"),
                                    inverse_linear(0.05, 0.01))
        assert a._epoch is not b._epoch

    def test_acc_fn_requires_eval_set(self):
        init, loss, acc = problem()
        bundle = protocol.ProblemBundle(init=init, loss=loss)
        with pytest.raises(ValueError):
            protocol.ProtocolEngine(bundle, make_pcfg(make_cfg()),
                                    inverse_linear(0.05, 0.01), acc_fn=acc)

    def test_collective_volume_model(self):
        # both engines lower to (G-1)·P pull + (G-1)·P push exchanges —
        # HLO-verified by repro.analyze (REPRO-HLO-COLLECTIVES); the old
        # "sharded ≈ 2·P" model was 4x off what XLA actually compiles
        sharded = make_pcfg(make_cfg(), "sharded")
        naive = make_pcfg(make_cfg(), "naive")
        P = 10_000
        assert protocol.collective_volume_bytes(naive, P) == \
            2 * (G - 1) * P * 4
        assert protocol.collective_volume_bytes(sharded, P) == \
            protocol.collective_volume_bytes(naive, P)
