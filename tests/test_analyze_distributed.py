"""Layer-2 compiled-artifact audit on 8 forced host devices (subprocess —
the device count must be set before jax initialises)."""
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_hlo_audit_end_to_end():
    runner = os.path.join(os.path.dirname(__file__), "_analyze_runner.py")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)          # the runner sets its own
    out = subprocess.run([sys.executable, runner], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ANALYZE_HLO_TESTS_PASS" in out.stdout, out.stdout
