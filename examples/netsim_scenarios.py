"""Scenario lab walkthrough: simulate a cluster, inspect its accounting, and
train ByzSGD over the *realized* delivery schedule — all through the
``netsim/*`` experiment presets (one spec = scenario + threat model + runner).

  PYTHONPATH=src python examples/netsim_scenarios.py                 # all
  PYTHONPATH=src python examples/netsim_scenarios.py --scenario crash_storm
"""
from __future__ import annotations

import argparse

import repro.exp as exp


def main(argv=None):
    netsim_presets = sorted(n for n in exp.names() if n.startswith("netsim/"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="all",
                    help=f"one of {[n.split('/', 1)[1] for n in netsim_presets]} "
                    "or 'all'")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    names = netsim_presets if args.scenario == "all" \
        else [f"netsim/{args.scenario}"]
    for name in names:
        # shrink the cluster + payload so the walkthrough stays snappy; every
        # field override re-validates the spec (paper Table 1 preconditions)
        e = exp.get(name, steps=args.steps, seed=args.seed, n_workers=7,
                    model_d=1000, metrics_every=max(args.steps // 4, 1))
        res = exp.run(e)
        print(res.netsim["summary"])
        print(f"  virtual time {res.netsim['virtual_ms']:.1f}ms  "
              f"events {res.netsim['events']}  "
              f"shortfalls {res.netsim['shortfalls']}  "
              f"mean pull staleness "
              f"{res.netsim['mean_pull_staleness_ms']:.2f}ms")
        for m in res.logs:
            extra = "".join(f"  {k} {v:7.2f}" for k, v in m.items()
                            if k.startswith("staleness"))
            print(f"  step {m['step']:3d}  acc {m['acc']:.3f}{extra}")
        print(f"  final acc {res.final['acc']:.3f}  "
              f"(spec {e.spec_hash})\n")


if __name__ == "__main__":
    main()
