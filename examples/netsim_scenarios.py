"""Scenario lab walkthrough: simulate a cluster, inspect its accounting, and
train ByzSGD over the *realized* delivery schedule.

  PYTHONPATH=src python examples/netsim_scenarios.py                 # all
  PYTHONPATH=src python examples/netsim_scenarios.py --scenario crash_storm
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.simulator import (ByzSGDConfig, ByzSGDSimulator,
                                  coordinatewise_diameter_sum)
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.netsim import ClusterSim, scenarios
from repro.optim.schedules import inverse_linear

MIX = MixtureSpec(n_classes=5, dim=16, sep=2.5)


def train_on_trace(sc, trace, steps: int):
    """ByzSGD on the small MLP problem, quorums replayed from the trace.
    Byzantine roles declared by the scenario are injected here (the network
    made those nodes slow; the attack makes them malicious too)."""
    byz = ByzantineSpec(worker_attack=sc.worker_attack,
                        server_attack=sc.server_attack,
                        n_byz_workers=sc.n_byz_workers,
                        n_byz_servers=sc.n_byz_servers,
                        equivocate=bool(sc.worker_attack or sc.server_attack))
    cfg = ByzSGDConfig(n_workers=sc.n_workers, f_workers=sc.f_workers,
                       n_servers=sc.n_servers, f_servers=sc.f_servers,
                       T=sc.T, gar=sc.gar, byz=byz)
    init, loss, acc = make_mlp_problem(dim=MIX.dim, hidden=32,
                                       n_classes=MIX.n_classes)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.01),
                          delivery=trace.to_delivery())
    state = sim.init_state(jax.random.PRNGKey(0))
    stream, eval_set = classification_stream(0, MIX, sc.n_workers, 16, steps)
    ex, ey = eval_set(512)
    state, logs = sim.run(state, stream, metrics_fn=lambda s: {
        "acc": float(acc(jax.tree.map(lambda l: l[0], s.params), ex, ey)),
        "delta": float(coordinatewise_diameter_sum(s.params, cfg.h_servers)),
    }, metrics_every=max(steps // 4, 1))
    return logs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="all",
                    help=f"one of {sorted(scenarios.SCENARIOS)} or 'all'")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    names = sorted(scenarios.SCENARIOS) if args.scenario == "all" \
        else [args.scenario]
    for name in names:
        sc = scenarios.get(name, steps=args.steps, seed=args.seed,
                           n_workers=7, model_d=1000)
        trace = ClusterSim(sc).run()
        print(trace.ledger.summary(sc))
        print(f"  virtual time {trace.step_done_ms[-1]:.1f}ms  "
              f"events {trace.events}  shortfalls {trace.shortfalls}  "
              f"mean pull staleness {trace.pull_stale.mean():.2f}ms")
        logs = train_on_trace(sc, trace, args.steps)
        for m in logs:
            extra = "".join(f"  {k} {v:7.2f}" for k, v in m.items()
                            if k.startswith("staleness"))
            print(f"  step {m['step']:3d}  acc {m['acc']:.3f}  "
                  f"diameter {m['delta']:8.3f}{extra}")
        print()


if __name__ == "__main__":
    main()
