"""Attack gallery: what breaks vanilla averaging, and what ByzSGD absorbs.

For each attack we run the same Experiment spec twice — once with the
non-resilient `mean` rule (the classical parameter-server baseline) and once
with a resilient rule from the repro.agg registry (MDA by default; pick any
with --gar) — and print final accuracies side by side.

    PYTHONPATH=src python examples/byzantine_attacks.py [--gar krum]
"""
import argparse

import repro.agg as agg
import repro.exp as exp
from repro.core.attacks import ByzantineSpec

BASE = exp.Experiment(name="attack_gallery", data="mixture10_easy",
                      steps=120, batch=25)


def train(gar: str, byz: ByzantineSpec) -> float:
    return exp.run(BASE.replace(gar=gar, byz=byz)).final["acc"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gar", default="mda",
                    choices=[n for n in agg.names()
                             if agg.get(n).tree_mode is not None and n != "mean"])
    args = ap.parse_args()
    spec = agg.get(args.gar)
    attacks = {
        "none": ByzantineSpec(),
        "reversed x10": ByzantineSpec(worker_attack="reversed",
                                      n_byz_workers=2,
                                      attack_kwargs=(("scale", 10.0),),
                                      equivocate=True),
        "ALIE": ByzantineSpec(worker_attack="alie", n_byz_workers=2,
                              equivocate=True),
        "sign flip": ByzantineSpec(worker_attack="sign_flip", n_byz_workers=2,
                                   equivocate=True),
    }
    col = f"{args.gar} (ByzSGD)"
    print(f"{'attack':14s} {'mean (vanilla)':>15s} {col:>16s}")
    for name, byz in attacks.items():
        a_mean = train("mean", byz)
        a_gar = train(args.gar, byz)
        print(f"{name:14s} {a_mean:15.3f} {a_gar:16.3f}")
    print(f"\naveraging 'does not tolerate a single corrupted input' (paper "
          f"§1); {args.gar} ({spec.doc}; breakdown {spec.breakdown}) does.")


if __name__ == "__main__":
    main()
