"""Attack gallery: what breaks vanilla averaging, and what ByzSGD absorbs.

For each attack we train twice — once with the non-resilient `mean` GAR (the
classical parameter-server baseline) and once with ByzSGD's MDA — and print
final accuracies side by side.

    PYTHONPATH=src python examples/byzantine_attacks.py
"""
import jax

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.simulator import ByzSGDConfig, ByzSGDSimulator
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear

MIX = MixtureSpec(n_classes=10, dim=32)


def train(gar: str, byz: ByzantineSpec, steps: int = 120) -> float:
    init, loss, accuracy = make_mlp_problem(dim=32, hidden=64)
    cfg = ByzSGDConfig(n_workers=9, f_workers=2, n_servers=5, f_servers=1,
                       T=10, gar=gar, byz=byz)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.005))
    state = sim.init_state(jax.random.PRNGKey(0))
    stream, eval_set = classification_stream(0, MIX, 9, 25, steps)
    ex, ey = eval_set(2048)
    state, _ = sim.run(state, stream)
    return float(accuracy(jax.tree.map(lambda l: l[0], state.params), ex, ey))


def main():
    attacks = {
        "none": ByzantineSpec(),
        "reversed x10": ByzantineSpec(worker_attack="reversed",
                                      n_byz_workers=2,
                                      attack_kwargs=(("scale", 10.0),),
                                      equivocate=True),
        "ALIE": ByzantineSpec(worker_attack="alie", n_byz_workers=2,
                              equivocate=True),
        "sign flip": ByzantineSpec(worker_attack="sign_flip", n_byz_workers=2,
                                   equivocate=True),
    }
    print(f"{'attack':14s} {'mean (vanilla)':>15s} {'MDA (ByzSGD)':>14s}")
    for name, byz in attacks.items():
        a_mean = train("mean", byz)
        a_mda = train("mda", byz)
        print(f"{name:14s} {a_mean:15.3f} {a_mda:14.3f}")
    print("\naveraging 'does not tolerate a single corrupted input' (paper "
          "§1); MDA does.")


if __name__ == "__main__":
    main()
