"""Attack gallery: what breaks vanilla averaging, and what ByzSGD absorbs.

For each attack we train twice — once with the non-resilient `mean` rule (the
classical parameter-server baseline) and once with a resilient rule from the
repro.agg registry (MDA by default; pick any with --gar) — and print final
accuracies side by side.

    PYTHONPATH=src python examples/byzantine_attacks.py [--gar krum]
"""
import argparse

import jax

import repro.agg as agg
from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.simulator import ByzSGDConfig, ByzSGDSimulator
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear

MIX = MixtureSpec(n_classes=10, dim=32)


def train(gar: str, byz: ByzantineSpec, steps: int = 120) -> float:
    init, loss, accuracy = make_mlp_problem(dim=32, hidden=64)
    cfg = ByzSGDConfig(n_workers=9, f_workers=2, n_servers=5, f_servers=1,
                       T=10, gar=gar, byz=byz)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.005))
    state = sim.init_state(jax.random.PRNGKey(0))
    stream, eval_set = classification_stream(0, MIX, 9, 25, steps)
    ex, ey = eval_set(2048)
    state, _ = sim.run(state, stream)
    return float(accuracy(jax.tree.map(lambda l: l[0], state.params), ex, ey))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gar", default="mda",
                    choices=[n for n in agg.names()
                             if agg.get(n).tree_mode is not None and n != "mean"])
    args = ap.parse_args()
    spec = agg.get(args.gar)
    attacks = {
        "none": ByzantineSpec(),
        "reversed x10": ByzantineSpec(worker_attack="reversed",
                                      n_byz_workers=2,
                                      attack_kwargs=(("scale", 10.0),),
                                      equivocate=True),
        "ALIE": ByzantineSpec(worker_attack="alie", n_byz_workers=2,
                              equivocate=True),
        "sign flip": ByzantineSpec(worker_attack="sign_flip", n_byz_workers=2,
                                   equivocate=True),
    }
    col = f"{args.gar} (ByzSGD)"
    print(f"{'attack':14s} {'mean (vanilla)':>15s} {col:>16s}")
    for name, byz in attacks.items():
        a_mean = train("mean", byz)
        a_gar = train(args.gar, byz)
        print(f"{name:14s} {a_mean:15.3f} {a_gar:16.3f}")
    print(f"\naveraging 'does not tolerate a single corrupted input' (paper "
          f"§1); {args.gar} ({spec.doc}; breakdown {spec.breakdown}) does.")


if __name__ == "__main__":
    main()
