"""Quickstart: Byzantine-resilient training in ~30 lines.

Runs ByzSGD (the paper's asynchronous variant) on a synthetic classification
task with 9 workers / 5 servers, 2 of the workers mounting the ALIE attack —
and converges anyway.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.simulator import ByzSGDConfig, ByzSGDSimulator
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear


def main():
    mix = MixtureSpec(n_classes=10, dim=32)
    init, loss, accuracy = make_mlp_problem(dim=32, hidden=64)

    cfg = ByzSGDConfig(
        n_workers=9, f_workers=2,      # n_w >= 3 f_w + 1
        n_servers=5, f_servers=1,      # n_ps >= 3 f_ps + 2
        T=10,                          # DMC gather every T steps
        gar="mda",                     # Minimum-Diameter Averaging — any
                                       # repro.agg registry rule works here
        byz=ByzantineSpec(worker_attack="alie", n_byz_workers=2,
                          equivocate=True),
    )
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.005))
    state = sim.init_state(jax.random.PRNGKey(0))

    stream, eval_set = classification_stream(seed=0, spec=mix,
                                             n_workers=cfg.n_workers,
                                             batch_per_worker=25, steps=150)
    ex, ey = eval_set(2048)
    state, logs = sim.run(state, stream, metrics_fn=lambda s: {
        "acc": float(accuracy(jax.tree.map(lambda l: l[0], s.params), ex, ey))},
        metrics_every=25)
    for m in logs:
        print(f"step {m['step']:4d}  accuracy {m['acc']:.3f}")
    print("\n2/9 workers ran the ALIE attack the whole time — MDA + "
          "scatter/gather absorbed it.")


if __name__ == "__main__":
    main()
