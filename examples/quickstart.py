"""Quickstart: Byzantine-resilient training in a few lines.

Runs the "quickstart" experiment preset — ByzSGD (the paper's asynchronous
variant) on a synthetic classification task with 9 workers / 5 servers, 2 of
the workers mounting the ALIE attack — and converges anyway. The preset is a
plain serializable spec; print ``e.to_dict()`` (or edit it) to see every knob.

    PYTHONPATH=src python examples/quickstart.py
"""
import repro.exp as exp


def main():
    e = exp.get("quickstart")          # a frozen, serializable Experiment
    print(f"spec {e.spec_hash}: {e.n_workers} workers "
          f"({e.byz.n_byz_workers} Byzantine, {e.byz.worker_attack}), "
          f"{e.n_servers} servers, gar={e.gar}, runner={e.runner}\n")

    res = exp.run(e)                   # fused epoch engine under the hood
    for m in res.logs[::3]:
        print(f"step {m['step']:4d}  accuracy {m['acc']:.3f}")
    print(f"final accuracy {res.final['acc']:.3f}  ({res.wall_s:.1f}s)")
    print(f"\n{e.byz.n_byz_workers}/{e.n_workers} workers ran the ALIE "
          "attack the whole time — MDA + scatter/gather absorbed it.")


if __name__ == "__main__":
    main()
