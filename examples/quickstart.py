"""Quickstart: Byzantine-resilient training in ~30 lines.

Runs ByzSGD (the paper's asynchronous variant) on a synthetic classification
task with 9 workers / 5 servers, 2 of the workers mounting the ALIE attack —
and converges anyway.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.engine import EpochEngine
from repro.core.simulator import ByzSGDConfig, ByzSGDSimulator
from repro.data.pipeline import DeviceBatchStream, MixtureSpec
from repro.optim.schedules import inverse_linear


def main():
    mix = MixtureSpec(n_classes=10, dim=32)
    init, loss, accuracy = make_mlp_problem(dim=32, hidden=64)

    cfg = ByzSGDConfig(
        n_workers=9, f_workers=2,      # n_w >= 3 f_w + 1
        n_servers=5, f_servers=1,      # n_ps >= 3 f_ps + 2
        T=10,                          # DMC gather every T steps
        gar="mda",                     # Minimum-Diameter Averaging — any
                                       # repro.agg registry rule works here
        byz=ByzantineSpec(worker_attack="alie", n_byz_workers=2,
                          equivocate=True),
    )
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.005))
    state = sim.init_state(jax.random.PRNGKey(0))

    # the fused epoch engine: batches are generated on device, whole T-step
    # epochs run as one compiled scan, metrics come back as one buffer
    stream = DeviceBatchStream(seed=0, spec=mix, n_workers=cfg.n_workers,
                               batch_per_worker=25)
    ex, ey = stream.eval_set(2048)
    engine = EpochEngine(sim, acc_fn=accuracy, eval_set=(ex, ey))
    state, metrics = engine.run(state, stream=stream, steps=150)
    for i in range(0, 150, 25):
        print(f"step {i:4d}  accuracy {metrics['acc'][i]:.3f}")
    print("\n2/9 workers ran the ALIE attack the whole time — MDA + "
          "scatter/gather absorbed it.")


if __name__ == "__main__":
    main()
