"""DMC in action: watch the server replicas drift apart during scatter and
snap together at every gather (Lemmas 4.2/4.3), with an ASCII trace of
Delta_t = the sum of coordinate-wise diameters.

    PYTHONPATH=src python examples/dmc_contraction.py
"""
import jax

from repro.configs.paper_models import make_mlp_problem
from repro.core.attacks import ByzantineSpec
from repro.core.simulator import (ByzSGDConfig, ByzSGDSimulator,
                                  coordinatewise_diameter_sum)
from repro.data.pipeline import MixtureSpec, classification_stream
from repro.optim.schedules import inverse_linear


def main():
    T = 8
    cfg = ByzSGDConfig(n_workers=9, f_workers=2, n_servers=5, f_servers=1,
                       T=T, byz=ByzantineSpec(server_attack="lie",
                                              n_byz_servers=1,
                                              equivocate=True))
    init, loss, _ = make_mlp_problem(dim=32, hidden=64)
    sim = ByzSGDSimulator(cfg, init, loss, inverse_linear(0.05, 0.005))
    state = sim.init_state(jax.random.PRNGKey(0))
    stream, _ = classification_stream(0, MixtureSpec(n_classes=10, dim=32),
                                      9, 25, 48)
    scatter = jax.jit(sim.scatter_step)
    gather = jax.jit(sim.gather_step)
    print("step  Delta_t   (# = drift, gather contracts; 1 LIE server active)")
    for i, batch in enumerate(stream):
        state = scatter(state, batch)
        d = float(coordinatewise_diameter_sum(state.params, cfg.h_servers))
        bar = "#" * min(int(d * 4), 70)
        print(f"{i:4d}  {d:8.4f}  {bar}")
        if (i + 1) % T == 0:
            state = gather(state)
            d2 = float(coordinatewise_diameter_sum(state.params,
                                                   cfg.h_servers))
            print(f"      {d2:8.4f}  {'#' * min(int(d2 * 4), 70)}  <- DMC "
                  f"gather (x{d2 / max(d, 1e-9):.2f})")


if __name__ == "__main__":
    main()
