"""End-to-end distributed ByzSGD LM training.

Trains a transformer with the full distributed protocol — per-group replicas,
masked-Median pulls, MDA aggregation, DMC gathers — on 8 forced host devices
(stand-ins for pod slices).

``--scale tiny`` runs the registered ``lm/tfm_tiny`` experiment preset
through :func:`repro.exp.run`: the protocol runner lights up the 2D
``(rep=4, fsdp=2)`` mesh and reports the "acc" metric (negative eval loss,
higher is better). ``--scale 100m`` drives the production launcher
(``repro.launch.train``) with checkpoint/restart at a production-ish width.

  # tiny model (fast demo)
  PYTHONPATH=src python examples/train_lm_distributed.py
  # ~100M-parameter model, a few hundred steps (several hours on 1 CPU core;
  # sized for a real accelerator host)
  PYTHONPATH=src python examples/train_lm_distributed.py --scale 100m --steps 300
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--attack", default=None,
                    help="e.g. alie (worker attack to inject)")
    args = ap.parse_args()

    if args.scale == "tiny":
        from repro import exp
        overrides = {"steps": args.steps}
        if args.attack:
            from repro.core.attacks import ByzantineSpec
            overrides["byz"] = ByzantineSpec(
                worker_attack=args.attack, n_byz_workers=1, equivocate=True)
        res = exp.run("lm/tfm_tiny", **overrides)
        print(f"[train_lm] lm/tfm_tiny mesh={res.provenance['mesh']} "
              f"steps={args.steps} final neg-eval-loss "
              f"{res.final['acc']:.3f}")
        return

    from repro.launch import train as train_mod

    # ~100M: reduced topology but production-ish width
    argv = ["--arch", "phi4-mini-3.8b", "--steps", str(args.steps),
            "--mesh", "4x2", "--groups", "4", "--T", "10",
            "--ckpt-dir", "/tmp/byzsgd_ckpt", "--ckpt-every", "25",
            "--reduced", "--seq", "256", "--batch-per-group", "4"]
    from repro.models import registry
    orig = registry.get_bundle

    def patched(arch_id, reduced=False, depth=None, **kw):
        return orig(arch_id, reduced=reduced, depth=depth,
                    n_layers=10, d_model=768, n_heads=12, n_kv_heads=4,
                    d_ff=3072, vocab=8192, head_dim=64, **kw)

    registry.get_bundle = patched
    if args.attack:
        argv += ["--worker-attack", args.attack, "--n-byz", "1"]
    train_mod.main(argv)


if __name__ == "__main__":
    main()
