"""End-to-end distributed ByzSGD LM training (the launch/train.py driver).

Trains a transformer with the full distributed protocol — per-group replicas,
masked-Median pulls, MDA aggregation, DMC gathers, checkpoint/restart — on 8
forced host devices (stand-ins for pod slices).

  # tiny model (fast demo)
  PYTHONPATH=src python examples/train_lm_distributed.py
  # ~100M-parameter model, a few hundred steps (several hours on 1 CPU core;
  # sized for a real accelerator host)
  PYTHONPATH=src python examples/train_lm_distributed.py --scale 100m --steps 300
"""
import argparse
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.argv0 = sys.argv[0]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--attack", default=None,
                    help="e.g. alie (worker attack to inject)")
    args = ap.parse_args()

    from repro.launch import train as train_mod

    argv = ["--arch", "phi4-mini-3.8b", "--steps", str(args.steps),
            "--mesh", "4x2", "--groups", "4", "--T", "10",
            "--ckpt-dir", "/tmp/byzsgd_ckpt", "--ckpt-every", "25"]
    if args.scale == "tiny":
        argv += ["--reduced", "--seq", "64", "--batch-per-group", "4"]
    else:
        # ~100M: reduced topology but production-ish width
        argv += ["--reduced", "--seq", "256", "--batch-per-group", "4"]
        from repro.models import registry
        orig = registry.get_bundle

        def patched(arch_id, reduced=False, depth=None, **kw):
            return orig(arch_id, reduced=reduced, depth=depth,
                        n_layers=10, d_model=768, n_heads=12, n_kv_heads=4,
                        d_ff=3072, vocab=8192, head_dim=64, **kw)

        registry.get_bundle = patched
    if args.attack:
        argv += ["--worker-attack", args.attack, "--n-byz", "1"]
    train_mod.main(argv)


if __name__ == "__main__":
    main()
